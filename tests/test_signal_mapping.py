"""Signal-processing -> fabric+GEMM mappings vs reference DSP."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import signal as sig
from repro.core import signal_mapping as sm


@pytest.mark.parametrize("n", [4, 8, 32, 128, 1024])
@pytest.mark.parametrize("fused", [False, True])
def test_fft_matches_numpy(n, fused):
    rng = np.random.default_rng(n)
    x = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    plan = sm.make_fft_plan(n, fuse_adjacent=fused)
    y = np.asarray(sm.fft_via_fabric(jnp.asarray(x), plan))
    np.testing.assert_allclose(y, np.fft.fft(x), rtol=1e-3, atol=1e-3)


def test_fft_plan_fusion_halves_traffic():
    full = sm.make_fft_plan(256, fuse_adjacent=False)
    fused = sm.make_fft_plan(256, fuse_adjacent=True)
    assert fused.shuffle_elements < 0.7 * full.shuffle_elements


def test_ifft_roundtrip_batched():
    rng = np.random.default_rng(3)
    x = (rng.standard_normal((3, 2, 64))
         + 1j * rng.standard_normal((3, 2, 64)))
    plan = sm.make_fft_plan(64)
    y = sm.ifft_via_fabric(sm.fft_via_fabric(jnp.asarray(x), plan), plan)
    np.testing.assert_allclose(np.asarray(y), x, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31), st.sampled_from([16, 64, 256]))
def test_fft_property(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    plan = sm.make_fft_plan(n)
    y = np.asarray(sm.fft_via_fabric(jnp.asarray(x), plan))
    np.testing.assert_allclose(y, np.fft.fft(x), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("taps", [1, 8, 20, 80])
def test_fir(taps):
    rng = np.random.default_rng(taps)
    n = 256
    x = rng.standard_normal(n)
    h = rng.standard_normal(taps)
    ref = np.convolve(x, h)[:n]
    y1 = np.asarray(sig.fir(jnp.asarray(x), jnp.asarray(h)))
    np.testing.assert_allclose(y1, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("phases", [2, 4, 8, 16])
def test_fir_phased_mapping(phases):
    """Beyond-paper multi-phase FIR == plain FIR."""
    rng = np.random.default_rng(phases)
    x = rng.standard_normal(256)
    h = rng.standard_normal(33)
    ref = np.convolve(x, h)[:256]
    y = np.asarray(sig.fir_phased(jnp.asarray(x), jnp.asarray(h), phases))
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)


def test_dct_orthonormal_and_2d():
    n = 32
    c = sm.dct_matrix(n)
    np.testing.assert_allclose(c @ c.T, np.eye(n), atol=1e-5)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, n, n)).astype(np.float32)
    y = np.asarray(sm.dct2_via_array(jnp.asarray(x)))
    ref = np.einsum("km,bmn,ln->bkl", c, x, c)
    np.testing.assert_allclose(y, ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("wavelet", ["haar", "db2"])
def test_dwt_perfect_reconstruction_energy(wavelet):
    """Orthogonal DWT preserves energy (Parseval)."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal(128)
    a, d = sig.dwt(jnp.asarray(x), wavelet)
    e_in = np.sum(x ** 2)
    e_out = float(jnp.sum(a ** 2) + jnp.sum(d ** 2))
    np.testing.assert_allclose(e_out, e_in, rtol=1e-4)


def test_stft_istft_roundtrip():
    rng = np.random.default_rng(7)
    x = rng.standard_normal(2048).astype(np.float32)
    S = sig.stft(jnp.asarray(x), 256, 128)
    xr = np.asarray(sig.istft(S, 128))
    np.testing.assert_allclose(xr[256:-256], x[256:2048 - 256],
                               rtol=1e-3, atol=1e-3)
