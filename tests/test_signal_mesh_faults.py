"""SigMesh fault tolerance: sharded SignalService parity on a forced
8-device mesh, device loss mid-stream with bit-identical resumed output,
retry/rollback and retry-exhaustion -> durable checkpoint restore +
journal replay (StreamSupervisor), straggler detection, and DecodeWave
snapshot/resume.

Multi-device tests run in subprocesses (tests/_mesh_helpers.py — the
forced device count must be set before jax imports); supervisor logic is
device-count-agnostic and runs in the main process on a *virtual*
8-shard :class:`SignalMesh` (logical shards wrap round-robin over the
single CPU device, so routing / affinity / checkpoint paths are the
same code).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _mesh_helpers import last_json
from repro.runtime import DeviceLoss, StepMonitor, StreamSupervisor
from repro.serving import DecodeWave, Request, SignalService
from repro.signal import SignalGraph

T = 1024


def _mask(p, z):
    return jax.nn.sigmoid(jnp.abs(z) - 1.0)


def _fig9(name="fig9"):
    g = SignalGraph(name)
    g.stft("spec", frame=256, hop=128)
    g.dnn("mask", "spec", fn=_mask)
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=128)
    g.outputs("out")
    return g


def _run_stream(svc, w, chunk=512, injector=None, sup_kw=None):
    """Feed ``w`` in chunks through one supervised session; returns the
    concatenated read()/close() stream and the supervisor."""
    sup = StreamSupervisor(svc, **(sup_kw or {}))
    sess = svc.open_stream("fig9")
    pieces = []
    empty = np.zeros(0, np.float32)
    for lo in range(0, len(w), chunk):
        sup.feed(sess, jnp.asarray(w[lo:lo + chunk]))
        sup.tick(injector)
        pieces.append(sess.read().get("out", empty))
    pieces.append(sess.close().get("out", empty))
    return np.concatenate(pieces, axis=-1), sup


def _reference_stream(w, chunk=512):
    svc = SignalService(batch_size=4)
    svc.register("fig9", _fig9())
    out, _ = _run_stream(svc, w, chunk)
    return out


# --------------------------------------------------------------------------
# Main-process supervisor contract (virtual 8-shard mesh, 1 CPU device)
# --------------------------------------------------------------------------

def test_transient_failure_rolls_back_and_retries_bit_identical():
    rng = np.random.default_rng(0)
    w = rng.standard_normal(4 * T).astype(np.float32)
    ref = _reference_stream(w)

    svc = SignalService(batch_size=4, mesh=8)
    svc.register("fig9", _fig9())
    fired = []

    def injector(tick, attempt):
        if tick == 2 and attempt == 0:
            fired.append(tick)
            raise RuntimeError("transient device error")

    out, sup = _run_stream(svc, w, injector=injector)
    assert fired == [2]
    np.testing.assert_array_equal(ref, out)
    assert sup.stats["retries"] == 1
    assert sup.stats["checkpoint_restores"] == 0


def test_retry_exhaustion_restores_durable_checkpoint_and_replays():
    rng = np.random.default_rng(1)
    w = rng.standard_normal(4 * T).astype(np.float32)
    ref = _reference_stream(w)

    svc = SignalService(batch_size=4, mesh=8)
    svc.register("fig9", _fig9())
    attempts = []

    def injector(tick, attempt):
        # persistent failure at tick 3: fails attempt 0..max_retries,
        # forcing the durable restore + journal replay path, then the
        # replacement node comes up clean (attempt resets to 0 and the
        # flag below stops further raises)
        if tick == 3 and len(attempts) <= 2:
            attempts.append(attempt)
            raise RuntimeError("persistent device error")

    out, sup = _run_stream(svc, w, injector=injector,
                           sup_kw={"ckpt_every": 2, "max_retries": 2})
    assert attempts == [0, 1, 2]
    np.testing.assert_array_equal(ref, out)
    assert sup.stats["checkpoint_restores"] == 1
    assert sup.stats["retries"] == 3


def test_straggler_hook_fires_on_slow_tick():
    rng = np.random.default_rng(2)
    w = rng.standard_normal(2 * T).astype(np.float32)
    svc = SignalService(batch_size=4, mesh=8)
    svc.register("fig9", _fig9())
    slow = []
    # factor 0: every tick after the first EWMA sample is a "straggler"
    out, sup = _run_stream(
        svc, w,
        sup_kw={"monitor": StepMonitor(straggler_factor=0.0),
                "on_straggler": lambda tick, dt: slow.append(tick)})
    assert slow, "straggler hook never fired"
    assert sup.monitor.stragglers == slow


def test_restore_detaches_sessions_opened_after_checkpoint():
    svc = SignalService(batch_size=4, mesh=8)
    svc.register("fig9", _fig9())
    ck = svc.checkpoint()
    sess = svc.open_stream("fig9")
    svc.restore(ck)
    assert sess.closed and "checkpoint" in sess.error
    with pytest.raises(ValueError):
        sess.feed(np.zeros(256, np.float32))
    assert svc.stats["detached_sessions"] == 1


# --------------------------------------------------------------------------
# DecodeWave checkpoint (LLM side of the co-scheduled service)
# --------------------------------------------------------------------------

def _tiny_engine(temperature=0.0):
    from repro.configs import get_config
    from repro.models.zoo import get_model
    from repro.serving import ServingEngine
    cfg = get_config("starcoder2-3b").reduced(
        n_layers=2, d_model=32, n_heads=4, d_ff=64, vocab=128)
    bundle = get_model(cfg)
    eng = ServingEngine(bundle, batch_size=2, temperature=temperature)
    eng.load(bundle.init(jax.random.PRNGKey(0)))
    return eng


def test_decode_wave_snapshot_resumes_identical_tokens():
    eng = _tiny_engine()
    reqs = [Request(rid=0, prompt=[1, 2, 3], max_new=6),
            Request(rid=1, prompt=[4, 5], max_new=6)]
    ref = DecodeWave(eng, [Request(rid=r.rid, prompt=list(r.prompt),
                                   max_new=r.max_new) for r in reqs])
    wave = DecodeWave(eng, reqs)
    for _ in range(3):
        ref.step()
        wave.step()
    snap = wave.snapshot()
    resumed = DecodeWave.from_snapshot(eng, snap)
    while not ref.done:
        ref.step()
    while not resumed.done:
        resumed.step()
    assert resumed.results() == ref.results()


def test_decode_wave_snapshot_requires_greedy():
    eng = _tiny_engine(temperature=0.7)
    wave = DecodeWave(eng, [Request(rid=0, prompt=[1, 2], max_new=3)])
    with pytest.raises(ValueError, match="greedy"):
        wave.snapshot()


# --------------------------------------------------------------------------
# Forced 8-device subprocess tests (real placement)
# --------------------------------------------------------------------------

def test_sharded_service_bit_identical_on_8_devices(forced_mesh):
    """Bucketed one-shot serving (even and uneven = masked buckets) and
    multi-output stream sessions produce bit-identical results sharded
    over 8 real (forced host) devices vs the unsharded service, and the
    per-device occupancy ledger sees every shard."""
    out = forced_mesh("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.serving import SignalService, SignalRequest, SignalMesh
        from repro.signal import SignalGraph

        def mask(p, z):
            return jax.nn.sigmoid(jnp.abs(z) - 1.0)

        def fig9(name="f"):
            g = SignalGraph(name)
            g.stft("spec", frame=256, hop=128)
            g.dnn("mask", "spec", fn=mask)
            g.mul("enh", "spec", "mask")
            g.istft("out", "enh", hop=128)
            g.magnitude("mag", "enh", onesided=True)
            g.mel_filterbank("mel", "mag", sr=16_000, n_mels=8)
            g.outputs("out", "mel")
            return g

        n_dev = len(jax.devices())
        rng = np.random.default_rng(0)
        # uneven lengths share a bucket -> masked execution over pad rows
        lens = [1024, 1024, 900, 700, 1024, 800, 640]
        sigs = [rng.standard_normal(n).astype(np.float32) for n in lens]
        reqs = lambda: [SignalRequest(rid=i, graph="f", samples=s)
                        for i, s in enumerate(sigs)]

        ref = SignalService(batch_size=4)
        ref.register("f", fig9())
        svc = SignalService(batch_size=4, mesh=SignalMesh(8))
        svc.register("f", fig9())
        r0, r1 = ref.serve(reqs()), svc.serve(reqs())
        serve_match = sorted(r0) == sorted(r1) and all(
            np.array_equal(r0[i]["out"], r1[i]["out"])
            and np.array_equal(r0[i]["mel"], r1[i]["mel"]) for i in r0)

        # multi-output stream sessions, device-affinity routed
        w = [rng.standard_normal(3 * 1024).astype(np.float32)
             for _ in range(4)]
        def drain(service):
            sessions = [service.open_stream("f") for _ in range(4)]
            got = [{"out": [], "mel": []} for _ in sessions]
            for lo in range(0, 3 * 1024, 512):
                for s, wi in zip(sessions, w):
                    s.feed(jnp.asarray(wi[lo:lo + 512]))
                service.stream_step()
                for g, s in zip(got, sessions):
                    for k, v in s.read().items():
                        g[k].append(v)
            for g, s in zip(got, sessions):
                for k, v in s.close().items():
                    g[k].append(v)
            # unbatched sessions: "out" is 1-D samples, "mel" pieces
            # concatenate along their leading frames axis
            axes = {"out": -1, "mel": 0}
            return [{k: np.concatenate(v, axis=axes[k])
                     for k, v in g.items()} for g in got], sessions

        g0, _ = drain(ref)
        g1, sessions = drain(svc)
        stream_match = all(
            np.array_equal(a["out"], b["out"])
            and np.array_equal(a["mel"], b["mel"])
            for a, b in zip(g0, g1))
        occ = svc.router.occupancy()
        print(json.dumps({
            "n_dev": n_dev,
            "serve_match": bool(serve_match),
            "stream_match": bool(stream_match),
            "session_devices": [s.device_index for s in sessions],
            "busy_devices": sum(1 for c in occ["device_cycles"] if c > 0),
            "wall_lt_est": bool(svc.wall_cycles < svc.est_cycles),
        }))
    """)
    r = last_json(out)
    assert r["n_dev"] == 8
    assert r["serve_match"] and r["stream_match"]
    # least-loaded routing spreads the 4 sessions over 4 distinct shards
    assert len(set(r["session_devices"])) == 4
    assert r["busy_devices"] == 8
    # the sharded wall clock beats the offered-work clock
    assert r["wall_lt_est"]


def test_device_loss_mid_stream_resumes_bit_identical_on_8_devices(
        forced_mesh):
    """Killing the shard a session is homed on mid-stream degrades to a
    restored, replayed, bit-identical stream on the surviving shards."""
    out = forced_mesh("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.runtime import DeviceLoss, StreamSupervisor
        from repro.serving import SignalService, SignalMesh
        from repro.signal import SignalGraph

        def mask(p, z):
            return jax.nn.sigmoid(jnp.abs(z) - 1.0)

        def fig9():
            g = SignalGraph("f")
            g.stft("spec", frame=256, hop=128)
            g.dnn("mask", "spec", fn=mask)
            g.mul("enh", "spec", "mask")
            g.istft("out", "enh", hop=128)
            g.outputs("out")
            return g

        rng = np.random.default_rng(7)
        w = rng.standard_normal(5 * 1024).astype(np.float32)

        def drain(service, injector=None):
            sup = StreamSupervisor(service, ckpt_every=2)
            sess = service.open_stream("f")
            pieces, devices = [], []
            empty = np.zeros(0, np.float32)
            for lo in range(0, len(w), 512):
                sup.feed(sess, jnp.asarray(w[lo:lo + 512]))
                sup.tick(None if injector is None
                         else (lambda t, a: injector(sess, t, a)))
                pieces.append(sess.read().get("out", empty))
                devices.append(sess.device_index)
            pieces.append(sess.close().get("out", empty))
            return np.concatenate(pieces, axis=-1), sup, devices

        ref = SignalService(batch_size=4)
        ref.register("f", fig9())
        expected, _, _ = drain(ref)

        svc = SignalService(batch_size=4, mesh=SignalMesh(8))
        svc.register("f", fig9())
        state = {"fired": False}

        def injector(sess, tick, attempt):
            if tick == 4 and not state["fired"]:
                state["fired"] = True
                raise DeviceLoss(sess.device_index)

        got, sup, devices = drain(svc, injector)
        print(json.dumps({
            "match": bool(np.array_equal(expected, got)),
            "fired": state["fired"],
            "device_losses": sup.stats["device_losses"],
            "alive": svc.router.alive_count(),
            "moved": len(set(devices)) > 1,
            "restores": sup.stats["checkpoint_restores"],
        }))
    """)
    r = last_json(out)
    assert r["fired"] and r["device_losses"] == 1
    assert r["alive"] == 7
    assert r["moved"], "session never re-homed off the dead shard"
    assert r["restores"] >= 1
    assert r["match"], "resumed stream is not bit-identical"
