"""SignalService batching + CoScheduler LLM/DSP interleaving."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.zoo import get_model
from repro.serving import (CoScheduler, Request, ServingEngine,
                           SignalRequest, SignalService)
from repro.signal import SignalGraph

T = 1024


def _fig9():
    g = SignalGraph("fig9")
    g.stft("spec", frame=256, hop=128)
    g.dnn("mask", "spec", fn=lambda p, z: jax.nn.sigmoid(jnp.abs(z) - 1.0))
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=128, length=T)
    g.output("out")
    return g


def _tiny_engine():
    cfg = get_config("starcoder2-3b").reduced(
        n_layers=2, d_model=32, n_heads=4, d_ff=64, vocab=128)
    bundle = get_model(cfg)
    eng = ServingEngine(bundle, batch_size=2)
    eng.load(bundle.init(jax.random.PRNGKey(0)))
    return eng


def test_service_batches_and_matches_offline():
    g = _fig9()
    svc = SignalService(batch_size=3)
    svc.register("fig9", g)
    rng = np.random.default_rng(0)
    sigs = [rng.standard_normal(T).astype(np.float32) for _ in range(5)]
    res = svc.serve([SignalRequest(rid=i, graph="fig9", samples=s)
                     for i, s in enumerate(sigs)])
    assert sorted(res) == [0, 1, 2, 3, 4]
    compiled = g.compile(T).jit()
    for i, s in enumerate(sigs):
        np.testing.assert_array_equal(
            res[i], np.asarray(compiled(jnp.asarray(s), None)))


def test_service_groups_by_length():
    # istft at natural length so the same graph serves multiple lengths
    g = SignalGraph("fig9n")
    g.stft("spec", frame=256, hop=128)
    g.dnn("mask", "spec", fn=lambda p, z: jax.nn.sigmoid(jnp.abs(z) - 1.0))
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=128)
    g.output("out")
    svc = SignalService(batch_size=8)
    svc.register("fig9", g)
    rng = np.random.default_rng(1)
    reqs = [SignalRequest(rid=0, graph="fig9",
                          samples=rng.standard_normal(T).astype(np.float32)),
            SignalRequest(rid=1, graph="fig9",
                          samples=rng.standard_normal(2 * T).astype(
                              np.float32))]
    for r in reqs:
        svc.submit(r)
    first = svc.step()          # only the length-T group executes
    assert list(first) == [0]
    assert svc.pending() == 1
    second = svc.step()
    assert list(second) == [1]
    assert second[1].shape[-1] == 2 * T


def test_coscheduler_interleaves_and_matches_standalone():
    """Acceptance: DSP requests are served through the same step loop as
    LLM decode, with results identical to each standalone path."""
    eng = _tiny_engine()
    svc = SignalService(batch_size=2)
    g = _fig9()
    svc.register("fig9", g)
    sched = CoScheduler(eng, svc)

    rng = np.random.default_rng(2)
    sigs = [rng.standard_normal(T).astype(np.float32) for _ in range(3)]
    llm_reqs = [Request(rid=i, prompt=[i + 1, i + 2, i + 3], max_new=4)
                for i in range(3)]
    for i, s in enumerate(sigs):
        sched.submit_signal(SignalRequest(rid=100 + i, graph="fig9",
                                          samples=s))
    for r in llm_reqs:
        sched.submit_llm(r)
    llm, dsp = sched.run()

    assert sorted(llm) == [0, 1, 2]
    assert sorted(dsp) == [100, 101, 102]
    # ticks interleaved both workloads rather than running them serially
    assert sched.ticks >= 4

    ref = eng.serve([Request(rid=r.rid, prompt=list(r.prompt),
                             max_new=r.max_new) for r in llm_reqs])
    assert llm == ref
    compiled = g.compile(T).jit()
    for i, s in enumerate(sigs):
        np.testing.assert_array_equal(
            dsp[100 + i], np.asarray(compiled(jnp.asarray(s), None)))
