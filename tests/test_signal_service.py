"""SignalService continuous batching (length buckets, masked execution),
streaming sessions, and the policy-driven CoScheduler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.zoo import get_model
from repro.serving import (CoScheduler, CostBalancedPolicy, DecodeWave,
                           Request, ServingEngine, SignalRequest,
                           SignalService, get_policy)
from repro.signal import SignalGraph, StreamingRunner

T = 1024


def _fig9():
    g = SignalGraph("fig9")
    g.stft("spec", frame=256, hop=128)
    g.dnn("mask", "spec", fn=lambda p, z: jax.nn.sigmoid(jnp.abs(z) - 1.0))
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=128, length=T)
    g.output("out")
    return g


def _fig9_natural(name="fig9n"):
    g = SignalGraph(name)
    g.stft("spec", frame=256, hop=128)
    g.dnn("mask", "spec", fn=lambda p, z: jax.nn.sigmoid(jnp.abs(z) - 1.0))
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=128)
    g.output("out")
    return g


def _tiny_engine():
    cfg = get_config("starcoder2-3b").reduced(
        n_layers=2, d_model=32, n_heads=4, d_ff=64, vocab=128)
    bundle = get_model(cfg)
    eng = ServingEngine(bundle, batch_size=2)
    eng.load(bundle.init(jax.random.PRNGKey(0)))
    return eng


def test_service_batches_and_matches_offline():
    g = _fig9()
    svc = SignalService(batch_size=3)
    svc.register("fig9", g)
    rng = np.random.default_rng(0)
    sigs = [rng.standard_normal(T).astype(np.float32) for _ in range(5)]
    res = svc.serve([SignalRequest(rid=i, graph="fig9", samples=s)
                     for i, s in enumerate(sigs)])
    assert sorted(res) == [0, 1, 2, 3, 4]
    compiled = g.compile(T).jit()
    for i, s in enumerate(sigs):
        np.testing.assert_array_equal(
            res[i], np.asarray(compiled(jnp.asarray(s), None)))


def test_service_groups_by_length():
    # istft at natural length so the same graph serves multiple lengths
    g = SignalGraph("fig9n")
    g.stft("spec", frame=256, hop=128)
    g.dnn("mask", "spec", fn=lambda p, z: jax.nn.sigmoid(jnp.abs(z) - 1.0))
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=128)
    g.output("out")
    svc = SignalService(batch_size=8)
    svc.register("fig9", g)
    rng = np.random.default_rng(1)
    reqs = [SignalRequest(rid=0, graph="fig9",
                          samples=rng.standard_normal(T).astype(np.float32)),
            SignalRequest(rid=1, graph="fig9",
                          samples=rng.standard_normal(2 * T).astype(
                              np.float32))]
    for r in reqs:
        svc.submit(r)
    first = svc.step()          # only the length-T group executes
    assert list(first) == [0]
    assert svc.pending() == 1
    second = svc.step()
    assert list(second) == [1]
    assert second[1].shape[-1] == 2 * T


def test_coscheduler_interleaves_and_matches_standalone():
    """Acceptance: DSP requests are served through the same step loop as
    LLM decode, with results identical to each standalone path."""
    eng = _tiny_engine()
    svc = SignalService(batch_size=2)
    g = _fig9()
    svc.register("fig9", g)
    sched = CoScheduler(eng, svc)

    rng = np.random.default_rng(2)
    sigs = [rng.standard_normal(T).astype(np.float32) for _ in range(3)]
    llm_reqs = [Request(rid=i, prompt=[i + 1, i + 2, i + 3], max_new=4)
                for i in range(3)]
    for i, s in enumerate(sigs):
        sched.submit_signal(SignalRequest(rid=100 + i, graph="fig9",
                                          samples=s))
    for r in llm_reqs:
        sched.submit_llm(r)
    llm, dsp = sched.run()

    assert sorted(llm) == [0, 1, 2]
    assert sorted(dsp) == [100, 101, 102]
    # ticks interleaved both workloads rather than running them serially
    assert sched.ticks >= 4

    ref = eng.serve([Request(rid=r.rid, prompt=list(r.prompt),
                             max_new=r.max_new) for r in llm_reqs])
    assert llm == ref
    compiled = g.compile(T).jit()
    for i, s in enumerate(sigs):
        np.testing.assert_array_equal(
            dsp[100 + i], np.asarray(compiled(jnp.asarray(s), None)))


# --------------------------------------------------------------------------
# Continuous batching: length buckets + masked execution
# --------------------------------------------------------------------------

def test_mixed_lengths_bucketed_bit_identical():
    """Acceptance: >= 4 distinct lengths execute via <= 2 bucket
    compilations, results bit-identical to per-request offline
    graph.compile(length)(x)."""
    g = _fig9_natural()
    svc = SignalService(batch_size=8)
    svc.register("fig9", g)
    rng = np.random.default_rng(10)
    lens = [700, 900, 1024, 1500, 1800]
    sigs = [rng.standard_normal(t).astype(np.float32) for t in lens]
    res = svc.serve([SignalRequest(rid=i, graph="fig9", samples=s)
                     for i, s in enumerate(sigs)])
    assert sorted(res) == list(range(len(lens)))
    assert svc.stats["compiles"] <= 2          # buckets 1024 and 2048
    assert svc.stats["batches"] == 2
    for i, (t, s) in enumerate(zip(lens, sigs)):
        off = np.asarray(g.compile(t)(jnp.asarray(s), None))
        np.testing.assert_array_equal(res[i], off)


def test_bucketed_requests_join_next_tick_midflight():
    """Continuous admission: a request submitted after a step joins the
    next step's wave for its bucket."""
    g = _fig9_natural()
    svc = SignalService(batch_size=4)
    svc.register("fig9", g)
    rng = np.random.default_rng(11)
    a = SignalRequest(rid=0, graph="fig9",
                      samples=rng.standard_normal(700).astype(np.float32))
    svc.submit(a)
    first = svc.step()
    assert list(first) == [0]
    # two new mixed-length requests of the same bucket arrive "mid-flight"
    b = SignalRequest(rid=1, graph="fig9",
                      samples=rng.standard_normal(800).astype(np.float32))
    c = SignalRequest(rid=2, graph="fig9",
                      samples=rng.standard_normal(1024).astype(np.float32))
    svc.submit(b)
    svc.submit(c)
    second = svc.step()
    assert sorted(second) == [1, 2]            # one batched call, one bucket
    assert svc.stats["compiles"] == 1          # same 1024 bucket throughout


def test_exact_length_fallback_for_non_maskable_graph():
    """Graphs whose math is global over the input axis (dct on raw
    samples) cannot be masked; they group by exact length as before."""
    g = SignalGraph("dct")
    g.dct("d", "input")
    g.output("d")
    svc = SignalService(batch_size=4)
    svc.register("dct", g)
    rng = np.random.default_rng(12)
    x1 = rng.standard_normal(48).astype(np.float32)
    x2 = rng.standard_normal(64).astype(np.float32)
    res = svc.serve([SignalRequest(rid=0, graph="dct", samples=x1),
                     SignalRequest(rid=1, graph="dct", samples=x2)])
    assert svc.stats["exact"] == 2 and svc.stats["bucketed"] == 0
    np.testing.assert_array_equal(
        res[0], np.asarray(g.compile(48)(jnp.asarray(x1), None)))
    np.testing.assert_array_equal(
        res[1], np.asarray(g.compile(64)(jnp.asarray(x2), None)))


def test_submit_validates_samples_early():
    svc = SignalService()
    svc.register("fig9", _fig9_natural())
    ok = np.zeros(512, np.float32)
    with pytest.raises(KeyError):
        svc.submit(SignalRequest(rid=0, graph="nope", samples=ok))
    with pytest.raises(ValueError, match="1-D"):
        svc.submit(SignalRequest(rid=1, graph="fig9",
                                 samples=np.zeros((2, 512), np.float32)))
    with pytest.raises(TypeError, match="real-valued"):
        svc.submit(SignalRequest(rid=2, graph="fig9",
                                 samples=np.zeros(512, np.complex64)))
    with pytest.raises(ValueError, match="too short"):
        svc.submit(SignalRequest(rid=3, graph="fig9",
                                 samples=np.zeros(100, np.float32)))
    # ints coerce to float32 instead of failing inside the jitted batch
    r = SignalRequest(rid=4, graph="fig9",
                      samples=np.arange(512, dtype=np.int32))
    svc.submit(r)
    assert r.samples.dtype == np.float32
    res = svc.step()
    assert 4 in res


def test_reregister_drops_queued_requests():
    """Regression: re-registering a name while requests are queued must
    not execute them against the replacement graph."""
    g1 = _fig9_natural("a")
    svc = SignalService(batch_size=4)
    svc.register("g", g1)
    rng = np.random.default_rng(13)
    stale = SignalRequest(rid=0, graph="g",
                          samples=rng.standard_normal(700).astype(np.float32))
    svc.submit(stale)
    g2 = SignalGraph("b")                      # different pipeline, same name
    g2.stft("spec", frame=512, hop=256)
    g2.istft("out", "spec", hop=256)
    g2.output("out")
    svc.register("g", g2)
    assert svc.pending() == 0                  # stale request dropped...
    assert stale.error is not None             # ...and told why
    assert svc.stats["dropped"] == 1
    fresh = SignalRequest(rid=1, graph="g",
                          samples=rng.standard_normal(1024).astype(
                              np.float32))
    res = svc.serve([fresh])                   # new graph serves cleanly
    np.testing.assert_array_equal(
        res[1], np.asarray(g2.compile(1024)(jnp.asarray(fresh.samples),
                                            None)))


# --------------------------------------------------------------------------
# Streaming sessions
# --------------------------------------------------------------------------

def test_stream_sessions_bit_identical_one_call_per_tick():
    """Acceptance: N concurrent sessions over the Fig-9 graph are
    bit-identical to offline, with ONE jitted core call per tick for
    same-graph lock-stepped sessions."""
    g = _fig9_natural()
    svc = SignalService(block_frames=4)
    svc.register("fig9", g)
    rng = np.random.default_rng(14)
    N, total, chunk = 3, 2048, 256
    waves = [rng.standard_normal(total).astype(np.float32)
             for _ in range(N)]
    sessions = [svc.open_stream("fig9") for _ in range(N)]
    outs = [[] for _ in range(N)]
    for lo in range(0, total, chunk):
        for s, w in zip(sessions, waves):
            s.feed(jnp.asarray(w[lo:lo + chunk]))
        calls = svc.stream_step()
        assert calls <= 1                      # batched, not per-session
        for i, s in enumerate(sessions):
            outs[i].append(s.read())
    for i, s in enumerate(sessions):
        outs[i].append(s.close())
    assert svc.stream_sessions() == 0          # all closed
    for i, w in enumerate(waves):
        got = np.concatenate([p for p in outs[i] if p.size], axis=-1)
        off = np.asarray(g.compile(total)(jnp.asarray(w), None))
        np.testing.assert_array_equal(got, off)


def test_stream_session_matches_private_runner():
    """A service session and a private StreamingRunner see identical
    streams (same chunking, same block size)."""
    g = _fig9_natural()
    svc = SignalService(block_frames=4)
    svc.register("fig9", g)
    rng = np.random.default_rng(15)
    w = rng.standard_normal(1700).astype(np.float32)
    sess = svc.open_stream("fig9")
    run = StreamingRunner(g, block_frames=4)
    got, ref = [], []
    for lo in (0, 300, 900):
        hi = {0: 300, 300: 900, 900: 1700}[lo]
        sess.feed(jnp.asarray(w[lo:hi]))
        svc.stream_step()
        got.append(sess.read())
        ref.append(np.asarray(run.process(jnp.asarray(w[lo:hi]))))
    got.append(sess.close())
    ref.append(np.asarray(run.flush()))
    got = np.concatenate([p for p in got if p.size], axis=-1)
    ref = np.concatenate([p for p in ref if p.size], axis=-1)
    np.testing.assert_array_equal(got, ref)


def test_open_stream_rejects_non_streamable():
    g = SignalGraph("dct")
    g.dct("d", "input")
    g.output("d")
    svc = SignalService()
    svc.register("dct", g)
    with pytest.raises(ValueError, match="not streamable"):
        svc.open_stream("dct")


# --------------------------------------------------------------------------
# Scheduling policies
# --------------------------------------------------------------------------

def test_latency_aware_serves_earliest_deadline_first():
    g = _fig9_natural()
    svc = SignalService(batch_size=1)          # one request per batch
    svc.register("fig9", g)
    rng = np.random.default_rng(16)
    done_order = []
    reqs = []
    for i, dl in enumerate([5.0, 1.0, 3.0]):   # rid 1 most urgent
        r = SignalRequest(rid=i, graph="fig9", deadline=dl,
                          samples=rng.standard_normal(T).astype(np.float32))
        reqs.append(r)
        svc.submit(r)
    pol = get_policy("latency_aware")

    class _Sched:
        signals = svc
        def llm_pending(self):
            return False
        def llm_earliest_deadline(self):
            import math
            return math.inf

    while svc.pending():
        plan = pol.plan(_Sched())
        res = svc.step(pick=svc.make_pick(plan.dsp_key, plan.dsp_order))
        done_order.extend(res)
    assert done_order == [1, 2, 0]             # earliest deadline first


def test_cost_balanced_policy_validates_target():
    with pytest.raises(ValueError):
        CostBalancedPolicy(dsp_target=1.5)
    assert get_policy(CostBalancedPolicy(0.3)).dsp_target == 0.3
    with pytest.raises(ValueError, match="unknown policy"):
        get_policy("nope")


def test_policies_complete_all_work():
    eng = _tiny_engine()
    rng = np.random.default_rng(17)
    for policy in ("latency_aware", "cost_balanced"):
        svc = SignalService(batch_size=2)
        g = _fig9()
        svc.register("fig9", g)
        sched = CoScheduler(eng, svc, policy=policy)
        sigs = [rng.standard_normal(T).astype(np.float32) for _ in range(3)]
        for i, s in enumerate(sigs):
            sched.submit_signal(SignalRequest(
                rid=100 + i, graph="fig9", deadline=float(i), samples=s))
            sched.submit_llm(Request(rid=i, prompt=[i + 1, i + 2],
                                     max_new=3, deadline=float(10 + i)))
        llm, dsp = sched.run()
        assert sorted(llm) == [0, 1, 2]
        assert sorted(dsp) == [100, 101, 102]
        occ = sched.occupancy()
        assert occ["llm_cycles"] > 0 and occ["dsp_cycles"] > 0
        # DSP outputs remain bit-identical under any policy
        compiled = g.compile(T).jit()
        for i, s in enumerate(sigs):
            np.testing.assert_array_equal(
                dsp[100 + i], np.asarray(compiled(jnp.asarray(s), None)))


def test_decode_wave_midflight_admission_greedy_identical():
    """A newcomer admitted into a free slot mid-flight continues exactly
    like a solo run when padded prefix lengths align (greedy decode is
    context-deterministic)."""
    eng = _tiny_engine()                       # batch_size=2, temperature 0
    short = Request(rid=0, prompt=[1, 2, 3], max_new=2)
    long = Request(rid=1, prompt=[4, 5, 6], max_new=6)
    wave = DecodeWave(eng, [short, long])
    wave.step()
    wave.step()                                # short done after 2 steps
    assert wave.free_slots() == 1
    # newcomer whose prompt length equals the active request's prefix
    # (3 prompt + 2 generated = 5) so left-padding stays aligned
    nc_prompt = [7, 8, 9, 10, 11]
    newcomer = Request(rid=2, prompt=nc_prompt, max_new=3)
    finished = wave.admit([newcomer])
    assert list(finished) == [0]
    while not wave.done:
        wave.step()
    res = wave.results()
    assert len(res[1]) == 6 and len(res[2]) == 3
    solo1 = eng.serve([Request(rid=1, prompt=[4, 5, 6], max_new=6)])
    solo2 = eng.serve([Request(rid=2, prompt=nc_prompt, max_new=3)])
    assert res[1] == solo1[1]
    assert res[2] == solo2[2]


def test_decode_wave_admission_requires_greedy():
    cfg = get_config("starcoder2-3b").reduced(
        n_layers=2, d_model=32, n_heads=4, d_ff=64, vocab=128)
    bundle = get_model(cfg)
    eng = ServingEngine(bundle, batch_size=2, temperature=0.7)
    eng.load(bundle.init(jax.random.PRNGKey(0)))
    wave = DecodeWave(eng, [Request(rid=0, prompt=[1, 2], max_new=2)])
    with pytest.raises(ValueError, match="greedy"):
        wave.admit([Request(rid=1, prompt=[3, 4], max_new=2)])


def test_decode_step_cost_positive_and_scales():
    eng = _tiny_engine()
    c1 = eng.decode_step_cost(1)
    c4 = eng.decode_step_cost(4)
    assert c1 > 0 and c4 >= c1


def test_latency_aware_streams_ride_along_llm_ticks():
    """Regression: ready stream blocks must advance even while
    deadline-bearing LLM traffic wins every EDF comparison (streaming
    connections are real-time; they ride along on LLM ticks)."""
    eng = _tiny_engine()
    svc = SignalService(block_frames=2)
    g = _fig9_natural()
    svc.register("fig9", g)
    sched = CoScheduler(eng, svc, policy="latency_aware")
    rng = np.random.default_rng(18)
    sess = svc.open_stream("fig9")
    sess.feed(jnp.asarray(rng.standard_normal(T).astype(np.float32)))
    for i in range(4):                         # urgent LLM traffic only
        sched.submit_llm(Request(rid=i, prompt=[1, 2, 3], max_new=6,
                                 deadline=1.0))
    for _ in range(3):
        sched.tick()
    assert svc.stats["core_calls"] > 0         # streams advanced
    got = [sess.read()]
    got.append(sess.close())
    assert sum(p.shape[-1] for p in got) > 0


def test_reregister_detaches_open_stream_sessions():
    """Regression: a live session's carried state was built under the
    old graph's frame/hop — replacement must detach it, not let it
    execute against the new registration."""
    g1 = _fig9_natural("a")
    svc = SignalService(block_frames=2)
    svc.register("g", g1)
    rng = np.random.default_rng(19)
    sess = svc.open_stream("g")
    sess.feed(jnp.asarray(rng.standard_normal(700).astype(np.float32)))
    g2 = SignalGraph("b")
    g2.stft("spec", frame=512, hop=256)       # different frame/hop
    g2.istft("out", "spec", hop=256)
    g2.output("out")
    svc.register("g", g2)
    assert sess.closed and sess.error is not None
    assert svc.stats["detached_sessions"] == 1
    with pytest.raises(ValueError, match="re-registered"):
        sess.feed(np.zeros(128, np.float32))
    assert svc.stream_step() == 0              # no crash, nothing to run
    sess2 = svc.open_stream("g")               # new sessions work
    sess2.feed(np.zeros(1024, np.float32))
    svc.stream_step()
    sess2.close()


def test_latency_aware_llm_progresses_alongside_streams():
    """Regression: deadline-less LLM traffic must advance while a
    continuously-fed stream session has ready blocks (no DSP-tie
    starvation)."""
    eng = _tiny_engine()
    svc = SignalService(block_frames=2)
    g = _fig9_natural()
    svc.register("fig9", g)
    sched = CoScheduler(eng, svc, policy="latency_aware")
    rng = np.random.default_rng(20)
    sess = svc.open_stream("fig9")
    for i in range(2):
        sched.submit_llm(Request(rid=i, prompt=[1, 2, 3], max_new=4))
    for _ in range(12):                        # keep the stream fed
        sess.feed(jnp.asarray(rng.standard_normal(256).astype(np.float32)))
        sched.tick()
    assert sorted(sched.llm_results) == [0, 1]  # LLM completed under load
    assert svc.stats["core_calls"] > 0          # stream advanced too
    sess.close()


def test_latency_aware_deadline_less_degrades_to_round_robin():
    """Regression: with no deadlines anywhere (inf == inf tie), EDF must
    not pick DSP forever — both classes advance every tick."""
    eng = _tiny_engine()
    svc = SignalService(batch_size=1)
    g = _fig9_natural()
    svc.register("fig9", g)
    sched = CoScheduler(eng, svc, policy="latency_aware")
    rng = np.random.default_rng(21)
    sched.submit_llm(Request(rid=0, prompt=[1, 2, 3], max_new=3))
    for i in range(4):                         # steady deadline-less DSP
        sched.submit_signal(SignalRequest(
            rid=100 + i, graph="fig9",
            samples=rng.standard_normal(T).astype(np.float32)))
        sched.tick()
    assert 0 in sched.llm_results              # LLM finished alongside DSP
    assert len(sched.dsp_results) >= 3


# --------------------------------------------------------------------------
# Durable checkpoints: stream snapshots persisted through Checkpointer
# --------------------------------------------------------------------------

def test_save_checkpoint_survives_process_death(tmp_path):
    """save_checkpoint writes the full service snapshot (open sessions,
    carried StreamState, pending reads, cycle counters) through the
    atomic Checkpointer; a *fresh* service object — no live template —
    restores from disk and continues the stream bit-identically."""
    rng = np.random.default_rng(7)
    w = rng.standard_normal(2 * T).astype(np.float32)

    svc = SignalService(batch_size=4)
    svc.register("fig9", _fig9_natural())
    sess = svc.open_stream("fig9")
    sess.feed(jnp.asarray(w[:T]))
    svc.stream_step()
    head = np.asarray(sess.read())
    step = svc.save_checkpoint(str(tmp_path / "ckpt"), blocking=True)
    assert (tmp_path / "ckpt" / f"step_{step:06d}" / "COMMIT").exists()

    # process death: nothing survives but the directory
    svc2 = SignalService(batch_size=4)
    svc2.register("fig9", _fig9_natural())
    got_step = svc2.restore_from_disk(str(tmp_path / "ckpt"))
    assert got_step == step
    sess2 = svc2.session_by_sid(sess.sid)
    assert sess2 is not None

    tails = []
    for s, svc_ in ((sess, svc), (sess2, svc2)):
        s.feed(jnp.asarray(w[T:]))
        svc_.stream_step()
        parts = [np.asarray(s.read()), np.asarray(s.close())]
        tails.append(np.concatenate(parts, axis=-1))
    np.testing.assert_array_equal(tails[0], tails[1])
    assert head.size + tails[0].size > 0


def test_save_checkpoint_keeps_last_n(tmp_path):
    svc = SignalService(batch_size=2)
    svc.register("fig9", _fig9_natural())
    for i in range(5):
        svc.save_checkpoint(str(tmp_path / "ckpt"), step=i, keep=2,
                            blocking=True)
    kept = sorted(p.name for p in (tmp_path / "ckpt").iterdir())
    assert kept == ["step_000003", "step_000004"]


def test_restore_from_disk_requires_sidecar(tmp_path):
    from repro.checkpoint import Checkpointer

    Checkpointer(str(tmp_path / "c")).save(0, [np.zeros(3)], blocking=True)
    svc = SignalService(batch_size=2)
    svc.register("fig9", _fig9_natural())
    with pytest.raises(ValueError, match="sidecar"):
        svc.restore_from_disk(str(tmp_path / "c"))
