"""SigMesh sharding properties, swept via tests/_hypothesis_compat.py.

Everything here runs in the main 1-CPU-device process on a *virtual*
:class:`SignalMesh` — logical shards wrap round-robin over the single
device, so padding math, least-loaded routing, per-device cost
accounting, and device-affinity invariance are plain host-side
properties (real placement is covered by the forced-8-device subprocess
tests in tests/test_signal_mesh_faults.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.perf_model import (device_step_costs, sharded_step_cost,
                                   step_cost_estimate,
                                   step_cost_estimate_per_device)
from repro.serving import SignalMesh, DeviceRouter, SignalService
from repro.serving.signal_mesh import trim_rows
from repro.signal import SignalGraph

FRAME, HOP = 64, 32


def _fig9():
    g = SignalGraph("g")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.dnn("mask", "spec", fn=lambda p, z: jax.nn.sigmoid(jnp.abs(z) - 1.0))
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=HOP)
    g.outputs("out")
    return g


# --------------------------------------------------------------------------
# Row padding / shard / trim round-trip
# --------------------------------------------------------------------------

@settings(max_examples=30)
@given(st.integers(1, 40), st.integers(1, 9))
def test_shard_trim_round_trip_on_uneven_rows(rows, n_shards):
    """pad -> shard -> trim is the identity on the real rows, for every
    (row count, shard count) mix including non-dividing ones."""
    mesh = SignalMesh(n_shards)
    padded = mesh.padded_rows(rows)
    assert padded >= rows and padded % n_shards == 0
    assert padded - rows < n_shards       # minimal padding
    rng = np.random.default_rng(rows * 100 + n_shards)
    stack = np.zeros((padded, 16), np.float32)
    real = rng.standard_normal((rows, 16)).astype(np.float32)
    stack[:rows] = real
    sharded = mesh.shard(stack)
    back = trim_rows(np.asarray(sharded), rows)
    np.testing.assert_array_equal(back, real)


@settings(max_examples=20)
@given(st.integers(1, 32), st.integers(1, 8))
def test_padded_rows_is_stable(rows, n_shards):
    """Padding an already-padded row count is a fixed point."""
    mesh = SignalMesh(n_shards)
    p = mesh.padded_rows(rows)
    assert mesh.padded_rows(p) == p


# --------------------------------------------------------------------------
# Per-device cost model consistency
# --------------------------------------------------------------------------

@settings(max_examples=30)
@given(st.integers(0, 64), st.integers(1, 9), st.integers(1, 5000))
def test_device_step_costs_consistent_with_totals(batch, n, per_item):
    costs = device_step_costs(per_item, batch, n)
    assert len(costs) == n
    # every device runs ceil(batch/n) rows (pad rows execute too), so
    # the per-device shares are equal and bound the unsharded cost
    assert len(set(costs)) == 1
    assert sharded_step_cost(per_item, batch, n) == max(costs, default=0)
    unsharded = step_like = per_item * batch
    if batch:
        assert max(costs) * n >= step_like
        assert max(costs) <= per_item * (batch // n + (batch % n > 0))


def test_step_cost_estimate_per_device_matches_step_cost_estimate():
    compiled = _fig9().compile(512)
    per_item = step_cost_estimate(compiled, batch=1)
    for n in (1, 2, 8):
        costs = step_cost_estimate_per_device(compiled, batch=4,
                                              n_devices=n)
        assert costs == device_step_costs(per_item, 4, n)
    # n_devices=1 degenerates to the unsharded estimate
    assert step_cost_estimate_per_device(compiled, batch=4,
                                         n_devices=1) == \
        [step_cost_estimate(compiled, batch=4)]


@settings(max_examples=20)
@given(st.integers(2, 8), st.integers(5, 40))
def test_router_greedy_assignment_is_balanced(n, sessions):
    """Least-loaded assignment keeps session counts within 1 of each
    other, whatever the open order."""
    r = DeviceRouter(n)
    for _ in range(sessions):
        r.assign()
    occ = r.occupancy()["sessions"]
    assert sum(occ) == sessions
    assert max(occ) - min(occ) <= 1


@settings(max_examples=20)
@given(st.integers(2, 8), st.integers(1, 6))
def test_router_drop_redirects_all_future_assignments(n, drops):
    r = DeviceRouter(n)
    dead = list(range(min(drops, n - 1)))
    for d in dead:
        r.drop(d)
    for _ in range(3 * n):
        assert r.assign() not in dead
    assert r.alive_count() == n - len(dead)


# --------------------------------------------------------------------------
# Service-level invariants (virtual mesh, 1 device)
# --------------------------------------------------------------------------

def test_per_device_occupancy_tracks_cost_model():
    """The router's cycle ledger for a one-shot serve equals the perf
    model's per-device estimate, summed over executed waves."""
    svc = SignalService(batch_size=4, mesh=SignalMesh(8))
    svc.register("g", _fig9())
    from repro.serving import SignalRequest
    rng = np.random.default_rng(3)
    reqs = [SignalRequest(rid=i, graph="g",
                          samples=rng.standard_normal(512).astype(
                              np.float32)) for i in range(4)]
    res = svc.serve(reqs)
    assert sorted(res) == [0, 1, 2, 3]
    per_item = svc.group_cost(("g", 512))
    expected = device_step_costs(per_item, 4, 8)
    assert svc.router.device_cycles == expected
    # the wall clock advanced by the max per-device share; the offered
    # work clock by the full batch cost
    assert svc.wall_cycles == max(expected)
    assert svc.est_cycles == per_item * 4


def test_session_affinity_invariant_across_ticks():
    """A session's carried state stays on its assigned shard for the
    whole stream, and each tick's cost lands on exactly that shard's
    ledger (router cycles match the service's _stream_cost charges)."""
    svc = SignalService(batch_size=4, mesh=SignalMesh(8))
    svc.register("g", _fig9())
    rng = np.random.default_rng(4)
    sessions = [svc.open_stream("g") for _ in range(3)]
    homes = [s.device_index for s in sessions]
    assert len(set(homes)) == 3           # spread over distinct shards
    charged = {d: 0 for d in homes}
    for _ in range(6):
        for s in sessions:
            s.feed(jnp.asarray(
                rng.standard_normal(128).astype(np.float32)))
        before = list(svc.router.device_cycles)
        svc.stream_step()
        for s, home in zip(sessions, homes):
            assert s.device_index == home
        for d in set(homes):
            charged[d] += svc.router.device_cycles[d] - before[d]
    # every shard that hosts a session did its own work (equal streams
    # -> equal ledgers), idle shards were never charged
    vals = {charged[d] for d in homes}
    assert len(vals) == 1 and vals != {0}
    for d in range(8):
        if d not in homes:
            assert svc.router.device_cycles[d] == 0
    for s in sessions:
        s.close()
    assert sum(svc.router.occupancy()["sessions"]) == 0
