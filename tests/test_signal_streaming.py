"""Streaming-vs-offline equivalence of SigStream graphs.

The exactness contract: chunked execution is bit-identical to offline for
IIR state continuation, the STFT->...->iSTFT core with pointwise or
conv-window (position-invariant) frame stages, at hop >= frame/2 where
overlap-add sums two commutative terms per sample.  Stages whose XLA
lowering is row-count dependent (FIR im2col GEMMs, dense per-frame
matmuls) match to a few float32 ULPs — the vectorization-remainder lanes
round differently for different array extents — and are tested at 1e-6.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.signal import SignalGraph, StreamingRunner

FRAME, HOP = 256, 128


def _stream(graph, x, splits, **kw):
    r = StreamingRunner(graph, **kw)
    pieces = [np.asarray(r.process(jnp.asarray(c)))
              for c in np.split(x, splits, axis=-1)]
    tail = np.asarray(r.flush())
    if tail.size:
        pieces.append(tail)
    return np.concatenate([p for p in pieces if p.size], axis=-1)


def test_streaming_iir_chain_bit_identical():
    T = 2048
    rng = np.random.default_rng(0)
    x = rng.standard_normal(T).astype(np.float32)
    g = SignalGraph("iir")
    g.iir_biquad("q", "input", b=[0.2, 0.3, 0.2], a=[1.0, -0.5, 0.25])
    g.iir_biquad("q2", "q", b=[0.5, 0.1, 0.0], a=[1.0, 0.2, 0.1])
    g.output("q2")
    off = np.asarray(g.compile(T)(jnp.asarray(x)))
    got = _stream(g, x, [177, 900, 901])
    assert np.array_equal(got, off)


def test_streaming_fir_chain_close():
    T = 2048
    rng = np.random.default_rng(1)
    x = rng.standard_normal(T).astype(np.float32)
    h = rng.standard_normal(9).astype(np.float32)
    g = SignalGraph("fir")
    g.fir("f", "input", taps=h)
    g.output("f")
    off = np.asarray(g.compile(T)(jnp.asarray(x)))
    got = _stream(g, x, [300, 1100])
    np.testing.assert_allclose(got, off, atol=1e-6, rtol=1e-6)


def test_streaming_stft_istft_core_bit_identical():
    T = 4096
    rng = np.random.default_rng(2)
    x = rng.standard_normal(T).astype(np.float32)
    g = SignalGraph("rt")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.istft("out", "spec", hop=HOP, length=T)
    g.output("out")
    off = np.asarray(g.compile(T)(jnp.asarray(x)))
    got = _stream(g, x, [300, 512, 700, 2500], block_frames=4)
    assert got.shape == off.shape
    assert np.array_equal(got, off)


def test_streaming_fig9_conv_mask_bit_identical():
    """Acceptance: the Fig-9 pipeline (stft -> conv-CNN mask -> istft)
    streams bit-identically to offline, across uneven chunk sizes and
    with DNN frame context carried over chunk boundaries."""
    T = 4096
    rng = np.random.default_rng(3)
    x = rng.standard_normal((2, T)).astype(np.float32)   # batched channels
    W = (rng.standard_normal((3, 3, 1, 1)) * 0.2).astype(np.float32)

    def conv_mask(p, z):
        m = jnp.abs(z)[..., None]
        squeeze = m.ndim == 3
        if squeeze:
            m = m[None]
        y = jax.lax.conv_general_dilated(
            m, jnp.asarray(W), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if squeeze:
            y = y[0]
        return jax.nn.sigmoid(y[..., 0])

    g = SignalGraph("fig9")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.dnn("mask", "spec", fn=conv_mask, frame_context=1)
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=HOP, length=T)
    g.output("out")

    off = np.asarray(g.compile(T)(jnp.asarray(x)))
    got = _stream(g, x, [300, 812, 1500, 3000], block_frames=4)
    assert got.shape == off.shape
    assert np.array_equal(got, off)


def test_streaming_pre_and_post_sample_stages():
    """fir -> stft -> mask -> istft -> iir: state carried on both sides."""
    T = 2048
    rng = np.random.default_rng(4)
    x = rng.standard_normal(T).astype(np.float32)
    h = (np.hanning(8) / 4).astype(np.float32)
    g = SignalGraph("chain")
    g.fir("pre", "input", taps=h)
    g.stft("spec", "pre", frame=FRAME, hop=HOP)
    g.dnn("mask", "spec", fn=lambda p, z: jax.nn.sigmoid(jnp.abs(z) - 1.0))
    g.mul("enh", "spec", "mask")
    g.istft("mid", "enh", hop=HOP, length=T)
    g.iir_biquad("post", "mid", b=[0.3, 0.2, 0.1], a=[1.0, -0.4, 0.2])
    g.output("post")
    off = np.asarray(g.compile(T)(jnp.asarray(x)))
    got = _stream(g, x, [333, 777, 1200])
    np.testing.assert_allclose(got, off, atol=2e-6, rtol=1e-5)


def test_streaming_v2_fusion_bit_identical_across_levels():
    """Carried-state offsets survive the v2-rewritten step list: the
    stream compiled with cross-einsum folding matches both the offline
    v2 graph and the completely unfused offline lowering, bit for bit."""
    T = 4096
    rng = np.random.default_rng(7)
    x = rng.standard_normal(T).astype(np.float32)
    g = SignalGraph("fig9")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.dnn("mask", "spec", fn=lambda p, z: jax.nn.sigmoid(jnp.abs(z) - 1.0))
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=HOP, length=T)
    g.output("out")
    off_unfused = np.asarray(g.compile(T, fuse=0)(jnp.asarray(x)))
    off_v2 = np.asarray(g.compile(T, fuse=2)(jnp.asarray(x)))
    got = _stream(g, x, [300, 812, 1500, 3000], block_frames=4, fuse=2)
    assert np.array_equal(off_v2, off_unfused)
    assert np.array_equal(got, off_v2)


def test_streaming_chunk_pattern_invariance():
    """Output is independent of how the input is chunked."""
    T = 2048
    rng = np.random.default_rng(5)
    x = rng.standard_normal(T).astype(np.float32)
    g = SignalGraph("rt")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.istft("out", "spec", hop=HOP, length=T)
    g.output("out")
    a = _stream(g, x, [100, 200, 400, 1000])
    b = _stream(g, x, [1024])
    assert np.array_equal(a, b)


def test_streaming_respects_short_istft_length():
    """istft(length < natural) caps the stream at every drain, matching
    the offline trim."""
    T, L = 4096, 1000
    rng = np.random.default_rng(6)
    x = rng.standard_normal((2, T)).astype(np.float32)
    g = SignalGraph("short")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.istft("out", "spec", hop=HOP, length=L)
    g.output("out")
    off = np.asarray(g.compile(T)(jnp.asarray(x)))
    got = _stream(g, x, [700, 1500, 3000], block_frames=4)
    assert got.shape == off.shape == (2, L)
    assert np.array_equal(got, off)


def test_streaming_sample_chain_flush_keeps_batch_rank():
    g = SignalGraph("fir")
    g.fir("f", "input", taps=[1.0, 0.5, 0.25])
    g.output("f")
    r = StreamingRunner(g)
    y = r.process(jnp.ones((2, 3, 64)))
    tail = r.flush()
    assert y.shape == (2, 3, 64)
    assert tail.shape == (2, 3, 0)
    np.concatenate([np.asarray(y), np.asarray(tail)], axis=-1)  # no raise


def test_streaming_rejects_non_streamable():
    g = SignalGraph("bad")
    g.stft("s1", frame=64, hop=32)
    g.istft("o1", "s1", hop=32)
    g.stft("s2", "o1", frame=64, hop=32)    # two framers
    g.istft("o2", "s2", hop=32)
    g.output("o2")
    with pytest.raises(ValueError):
        StreamingRunner(g)

    g2 = SignalGraph("bad2")
    g2.dct("d", "input")                    # dct over raw samples: offline-only
    g2.output("d")
    with pytest.raises(ValueError):
        StreamingRunner(g2)


def test_stream_state_is_stackable_pytree():
    """Lock-stepped connections' carried states stack/unstack across a
    leading batch axis (what the service's batched sessions rely on)."""
    from repro.signal.streaming import stack_states, unstack_states

    T, chunk = 1024, 256
    rng = np.random.default_rng(8)
    g = SignalGraph("rt")
    g.fir("pre", "input", taps=np.hanning(8) / 4)
    g.stft("spec", "pre", frame=FRAME, hop=HOP)
    g.istft("out", "spec", hop=HOP)
    g.output("out")
    runners = [StreamingRunner(g, block_frames=4) for _ in range(2)]
    waves = [rng.standard_normal(T).astype(np.float32) for _ in range(2)]
    for r, w in zip(runners, waves):
        r.process(jnp.asarray(w[:chunk]))
        r.process(jnp.asarray(w[chunk:2 * chunk]))
    stacked = stack_states([r.state for r in runners])
    assert stacked.buf.shape[0] == 2           # new leading batch axis
    back = unstack_states(stacked, 2)
    for r, s in zip(runners, back):
        assert s.total == r.state.total and s.f_next == r.state.f_next
        np.testing.assert_array_equal(np.asarray(s.buf),
                                      np.asarray(r.state.buf))
        for a, b in zip(s.pre, r.state.pre):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # out-of-step states refuse to stack
    runners[0].process(jnp.asarray(waves[0][2 * chunk:3 * chunk]))
    with pytest.raises(ValueError, match="lock-step"):
        stack_states([r.state for r in runners])


def test_stream_structure_analysis_fields():
    from repro.signal import StreamStructure

    g = SignalGraph("chain")
    g.fir("pre", "input", taps=[1.0, 0.5])
    g.stft("spec", "pre", frame=FRAME, hop=HOP)
    g.dnn("mask", "spec", fn=lambda p, z: z, frame_context=2)
    g.mul("enh", "spec", "mask")
    g.istft("mid", "enh", hop=HOP, length=1000)
    g.iir_biquad("post", "mid", b=[0.3, 0.2, 0.1], a=[1.0, -0.4, 0.2])
    g.output("post")
    s = StreamStructure.analyze(g)
    assert s.pre_names == ["pre"] and s.post_names == ["post"]
    assert s.framer == "spec" and s.deframer == "mid"
    assert (s.frame, s.hop, s.context, s.out_length) == (FRAME, HOP, 2,
                                                         1000)
    assert s.min_length == FRAME
    assert s.valid_frames(FRAME) == 1
    assert s.out_count(2048) == 1000           # declared istft length wins

    # frames-domain frontend: analyzable (bucketable) but not streamable
    f = SignalGraph("mel")
    f.stft("spec", frame=FRAME, hop=HOP)
    f.magnitude("mag", "spec", onesided=True)
    f.mel_filterbank("mel", "mag", sr=16_000, n_mels=8)
    f.output("mel")
    fs = StreamStructure.analyze(f)
    assert fs.deframer is None
    assert fs.out_count(FRAME + 3 * HOP) == 4  # valid frame rows
    with pytest.raises(ValueError):
        StreamingRunner(f)                     # no istft: cannot stream

    bad = SignalGraph("dct")
    bad.dct("d", "input")
    bad.output("d")
    with pytest.raises(ValueError):
        StreamStructure.analyze(bad)


def test_shared_structure_core_cache_across_runners():
    """Runners built on one StreamStructure share compiled core programs
    (what keeps N sessions at one compile per block shape)."""
    from repro.signal import StreamStructure

    g = SignalGraph("rt")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.istft("out", "spec", hop=HOP)
    g.output("out")
    struct = StreamStructure.analyze(g)
    r1 = StreamingRunner(g, block_frames=4, struct=struct)
    r2 = StreamingRunner(g, block_frames=4, struct=struct)
    rng = np.random.default_rng(9)
    w = rng.standard_normal(1024).astype(np.float32)
    r1.process(jnp.asarray(w))
    r2.process(jnp.asarray(w))
    assert len(struct._core_cache) >= 1
    assert r1.struct is r2.struct


# --------------------------------------------------------------------------
# Per-output deadlines: early tap ahead of the deframed stream
# --------------------------------------------------------------------------

def _deadline_graph(deadline=None):
    g = SignalGraph("dl")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.dnn("mask", "spec", fn=lambda p, z: jax.nn.sigmoid(jnp.abs(z) - 1.0))
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=HOP)
    if deadline is None:
        g.outputs("out")
    else:
        g.outputs("out", deadline=deadline)
    return g


def test_deadline_hint_adds_framer_tap():
    """outputs(deadline=...) on a deframed output makes the analysis
    surface the framer as a cheap frames-domain tap: frames flow with
    zero frame latency while the overlap-add output trails by
    frame - hop samples — the early signal a deadline consumer needs."""
    from repro.signal import StreamStructure

    s = StreamStructure.analyze(_deadline_graph(deadline=5e-3))
    assert s.deadlines == {"out": 5e-3}
    assert s.early_taps == ["spec"]
    assert "spec" in s.frame_outputs
    lat = s.output_latencies()
    assert lat["out"]["deadline"] == 5e-3
    assert lat["spec"] == {"domain": "frames", "latency": 0,
                           "early_tap": True}

    # a chunk emits tap frames ahead of the deframed samples
    r = StreamingRunner(_deadline_graph(deadline=5e-3))
    rng = np.random.default_rng(11)
    got = r.process(jnp.asarray(
        rng.standard_normal(4 * FRAME).astype(np.float32)))
    n_frames = 1 + (4 * FRAME - FRAME) // HOP
    assert got["spec"].shape == (n_frames, FRAME)
    assert got["out"].shape[-1] < 4 * FRAME      # samples still trailing


def test_deadline_free_graph_has_no_tap():
    """No deadline -> no hidden extra outputs (regression guard: the
    tap must never change deadline-free graph results)."""
    from repro.signal import StreamStructure

    s = StreamStructure.analyze(_deadline_graph())
    assert s.deadlines == {} and s.early_taps == []
    assert s.frame_outputs == []


def test_deadline_validates_output_names():
    g = _deadline_graph()
    with pytest.raises(ValueError, match="non-output stage"):
        g.outputs("out", deadline={"mask": 1e-3})
