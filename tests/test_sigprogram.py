"""The SigProgram contract: multi-output SignalGraphs (outputs / tap),
DAG pruning, per-output results across offline / streaming / serving,
shared-prefix report attribution, params pytree + value_and_grad, and
the deprecated single-output() spelling."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.perf_model import signal_graph_report
from repro.serving import SignalRequest, SignalService
from repro.signal import SignalGraph, StreamingRunner

FRAME, HOP = 256, 128


def _mask(p, z):
    return jax.nn.sigmoid(jnp.abs(z) - 1.0)


def _fig9_tapped(length=None, n_mels=8):
    """Fig-9 enhancement with a mel monitoring tap: outputs('out',
    'mel_tap') — ONE graph, one compiled core program."""
    g = SignalGraph("fig9_tapped")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.dnn("mask", "spec", fn=_mask)
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=HOP, length=length)
    g.magnitude("mag", "enh", onesided=True)
    g.mel_filterbank("mel_tap", "mag", sr=16_000, n_mels=n_mels)
    g.outputs("out", "mel_tap")
    return g


def _fig9_single(output, length=None, n_mels=8, name="fig9_single"):
    """The same pipeline compiled with ONE declared output."""
    g = SignalGraph(name)
    g.stft("spec", frame=FRAME, hop=HOP)
    g.dnn("mask", "spec", fn=_mask)
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=HOP, length=length)
    g.magnitude("mag", "enh", onesided=True)
    g.mel_filterbank("mel_tap", "mag", sr=16_000, n_mels=n_mels)
    g.outputs(output)
    return g


# --------------------------------------------------------------------------
# Offline contract
# --------------------------------------------------------------------------

def test_outputs_returns_ordered_dict_and_prunes_dead_stages():
    T = 1024
    g = SignalGraph("p")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.magnitude("mag", "spec", onesided=True)
    g.mel_filterbank("mel", "mag", sr=16_000, n_mels=4)
    g.dct("dead", "mel")                  # consumes mel but feeds nothing
    g.outputs("mel", "mag")
    c = g.compile(T)
    assert c.outputs == ("mel", "mag")
    assert [s.name for s in c.stages] == ["spec", "mag", "mel"]  # pruned
    x = jnp.asarray(np.random.default_rng(0).standard_normal(T), jnp.float32)
    res = c(x)
    assert list(res) == ["mel", "mag"]    # declaration order
    assert res["mel"].shape[-1] == 4


def test_multi_output_bit_identical_to_two_single_compiles():
    """Acceptance: the Fig-9 graph compiled with outputs('out',
    'mel_tap') matches two independent single-output compiles bitwise,
    offline."""
    T = 2048
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(T), jnp.float32)
    multi = _fig9_tapped(length=T).compile(T)
    res = multi(x)
    out1 = _fig9_single("out", length=T).compile(T)(x)
    out2 = _fig9_single("mel_tap", length=T).compile(T)(x)
    np.testing.assert_array_equal(np.asarray(res["out"]),
                                  np.asarray(out1["out"]))
    np.testing.assert_array_equal(np.asarray(res["mel_tap"]),
                                  np.asarray(out2["mel_tap"]))


def test_shared_prefix_lowered_once_in_report():
    """Acceptance: signal_graph_report shows the shared prefix is
    lowered once — the shared bucket's passes appear once in the
    multi-output totals, and the totals sit strictly under two
    single-output compiles."""
    T = 2048
    multi = _fig9_tapped(length=T).compile(T)
    rep = signal_graph_report(multi)
    assert rep["outputs"] == ["out", "mel_tap"]
    per = rep["per_output"]
    assert set(per) == {"out", "mel_tap", "shared"}
    # stft + mask + mul are shared; mel's GEMM is exclusive to the tap
    assert "spec" in per["shared"]["stages"]
    assert "mel_tap" in per["mel_tap"]["stages"]
    # buckets partition the totals: every pass is counted exactly once
    assert sum(b["fabric_passes"] for b in per.values()) \
        == rep["fabric_passes"]
    assert sum(b["shuffle_words"] for b in per.values()) \
        == rep["shuffle_words"]
    # two single-output compiles pay the shared prefix twice
    s1 = signal_graph_report(_fig9_single("out", length=T).compile(T))
    s2 = signal_graph_report(_fig9_single("mel_tap", length=T).compile(T))
    assert rep["fabric_passes"] < s1["fabric_passes"] + s2["fabric_passes"]
    assert rep["shuffle_words"] < s1["shuffle_words"] + s2["shuffle_words"]
    assert rep["macs"] < s1["macs"] + s2["macs"]


def test_tap_appends_diagnostic_output():
    T = 1024
    g = SignalGraph("t")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.dnn("mask", "spec", fn=_mask)
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=HOP, length=T)
    g.outputs("out")
    g.tap("mask")
    g.tap("mask")                          # idempotent
    c = g.compile(T)
    assert c.outputs == ("out", "mask")
    x = jnp.asarray(np.random.default_rng(2).standard_normal(T), jnp.float32)
    res = c(x)
    assert set(res) == {"out", "mask"}
    assert res["mask"].shape == (7, FRAME)
    with pytest.raises(ValueError, match="zzz"):
        g.tap("zzz")


def test_deprecated_output_warns_and_returns_bare_array():
    T = 1024
    g = SignalGraph("d")
    g.stft("spec", frame=FRAME, hop=HOP)
    g.istft("out", "spec", hop=HOP, length=T)
    with pytest.warns(DeprecationWarning, match="outputs"):
        g.output("out")
    c = g.compile(T)
    x = jnp.asarray(np.random.default_rng(3).standard_normal(T), jnp.float32)
    y = c(x)
    assert not isinstance(y, dict) and y.shape == (T,)
    # the plural spelling of the same single output returns a dict
    g2 = SignalGraph("d2")
    g2.stft("spec", frame=FRAME, hop=HOP)
    g2.istft("out", "spec", hop=HOP, length=T)
    g2.outputs("out")
    res = g2.compile(T)(x)
    assert isinstance(res, dict) and list(res) == ["out"]
    np.testing.assert_array_equal(np.asarray(res["out"]), np.asarray(y))


def test_add_and_outputs_validation_name_the_offender():
    g = SignalGraph("v")
    g.fft("a", "input")
    with pytest.raises(ValueError, match="'a'"):
        g.add("fft", "a", "input")         # duplicate stage name
    with pytest.raises(ValueError, match="'nope'"):
        g.add("fft", "b", "nope")          # undefined input reference
    with pytest.raises(ValueError, match="'input'|duplicate"):
        g.add("fft", "input", "a")         # reserved graph-input name
    with pytest.raises(ValueError, match="'ghost'"):
        g.outputs("a", "ghost")
    with pytest.raises(ValueError, match="at least one"):
        g.outputs()
    with pytest.raises(ValueError, match="duplicate"):
        g.outputs("a", "a")


# --------------------------------------------------------------------------
# Streaming + serving contract
# --------------------------------------------------------------------------

def test_streaming_runner_multi_output_matches_offline():
    T = 4096
    rng = np.random.default_rng(4)
    x = rng.standard_normal(T).astype(np.float32)
    g = _fig9_tapped(length=T)
    off = g.compile(T)(jnp.asarray(x))
    r = StreamingRunner(g, block_frames=4)
    acc = {}
    for c in np.split(x, [300, 812, 1500, 3000], axis=-1):
        for k, v in r.process(jnp.asarray(c)).items():
            acc.setdefault(k, []).append(np.asarray(v))
    for k, v in r.flush().items():
        acc.setdefault(k, []).append(np.asarray(v))
    got_out = np.concatenate([p for p in acc["out"] if p.size], axis=-1)
    got_mel = np.concatenate([p for p in acc["mel_tap"] if p.size], axis=0)
    # deframed stream: bit-identical; frame tap: the mel GEMM's XLA
    # lowering is row-count dependent (the documented FIR-GEMM ULP
    # caveat at block scope)
    np.testing.assert_array_equal(got_out, np.asarray(off["out"]))
    np.testing.assert_allclose(got_mel, np.asarray(off["mel_tap"]),
                               rtol=1e-5, atol=1e-4)
    lat = r.struct.output_latencies()
    assert lat["out"] == {"domain": "samples", "latency": FRAME - HOP}
    assert lat["mel_tap"] == {"domain": "frames", "latency": 0}


def test_stream_session_multi_output_one_core_call_per_tick():
    """Acceptance: the Fig-9 tapped graph served via StreamSession emits
    per-output results matching offline, still ONE jitted core call per
    tick for lock-stepped sessions."""
    g = _fig9_tapped()                     # natural istft length
    svc = SignalService(block_frames=4)
    svc.register("fig9", g)
    rng = np.random.default_rng(5)
    N, total, chunk = 3, 2048, 256
    waves = [rng.standard_normal(total).astype(np.float32)
             for _ in range(N)]
    sessions = [svc.open_stream("fig9") for _ in range(N)]
    accs = [{} for _ in range(N)]
    for lo in range(0, total, chunk):
        for s, w in zip(sessions, waves):
            s.feed(jnp.asarray(w[lo:lo + chunk]))
        assert svc.stream_step() <= 1      # batched, not per-session
        for i, s in enumerate(sessions):
            for k, v in s.read().items():
                accs[i].setdefault(k, []).append(v)
    for i, s in enumerate(sessions):
        for k, v in s.close().items():
            accs[i].setdefault(k, []).append(v)
    assert svc.stream_sessions() == 0
    for i, w in enumerate(waves):
        off = g.compile(total)(jnp.asarray(w))
        got_out = np.concatenate(accs[i]["out"], axis=-1)
        got_mel = np.concatenate(accs[i]["mel_tap"], axis=0)
        np.testing.assert_array_equal(got_out, np.asarray(off["out"]))
        np.testing.assert_allclose(got_mel, np.asarray(off["mel_tap"]),
                                   rtol=1e-5, atol=1e-4)


def test_service_submit_multi_output_per_request_results():
    """Acceptance: SignalService.submit returns per-output dicts, each
    output trimmed to the request's true length, equal to per-length
    offline compiles (bucketed masked execution underneath)."""
    g = _fig9_tapped()                     # natural istft length
    svc = SignalService(batch_size=8)
    svc.register("fig9", g)
    rng = np.random.default_rng(6)
    lens = [700, 900, 1024, 1500]
    sigs = [rng.standard_normal(t).astype(np.float32) for t in lens]
    res = svc.serve([SignalRequest(rid=i, graph="fig9", samples=s)
                     for i, s in enumerate(sigs)])
    assert svc.stats["compiles"] <= 2      # buckets 1024 and 2048
    for i, (t, s) in enumerate(zip(lens, sigs)):
        off = g.compile(t)(jnp.asarray(s))
        assert set(res[i]) == {"out", "mel_tap"}
        np.testing.assert_array_equal(res[i]["out"],
                                      np.asarray(off["out"]))
        np.testing.assert_allclose(res[i]["mel_tap"],
                                   np.asarray(off["mel_tap"]),
                                   rtol=1e-5, atol=1e-4)


def test_sample_chain_taps_stream_with_zero_latency():
    """Multi-output pure sample chains: mid-chain taps emit with every
    chunk (causal, no core, no latency)."""
    T = 1024
    rng = np.random.default_rng(7)
    x = rng.standard_normal(T).astype(np.float32)
    g = SignalGraph("chain")
    g.fir("f1", "input", taps=[1.0, 0.5, 0.25])
    g.iir_biquad("q", "f1", b=[0.2, 0.3, 0.2], a=[1.0, -0.5, 0.25])
    g.outputs("q", "f1")
    off = g.compile(T)(jnp.asarray(x))
    r = StreamingRunner(g)
    acc = {}
    for c in np.split(x, [300, 700], axis=-1):
        outs = r.process(jnp.asarray(c))
        assert set(outs) == {"q", "f1"}    # both emit immediately
        for k, v in outs.items():
            acc.setdefault(k, []).append(np.asarray(v))
    for k in ("q", "f1"):
        got = np.concatenate(acc[k], axis=-1)
        np.testing.assert_allclose(got, np.asarray(off[k]),
                                   atol=1e-6, rtol=1e-6)
    lat = r.struct.output_latencies()
    assert lat["q"]["latency"] == 0 and lat["f1"]["latency"] == 0


# --------------------------------------------------------------------------
# Params pytree
# --------------------------------------------------------------------------

def test_init_params_collects_learnable_stages():
    g = SignalGraph("lp")
    g.fir("front", "input", taps=np.hanning(8) / 4)
    g.stft("spec", "front", frame=FRAME, hop=HOP)
    g.dnn("mask", "spec", fn=_mask, init={"w": np.ones(3, np.float32)})
    g.mul("enh", "spec", "mask")
    g.istft("out", "enh", hop=HOP)
    g.magnitude("mag", "enh", onesided=True)
    g.mel_filterbank("mel", "mag", sr=16_000, n_mels=6)
    g.outputs("out", "mel")
    c = g.compile(1024)
    p = c.init_params()
    assert set(p) == {"front", "mask", "mel"}
    assert p["front"]["taps"].shape == (8,)
    assert p["mel"]["weights"].shape == (6, FRAME // 2 + 1)
    np.testing.assert_array_equal(p["mask"]["w"], np.ones(3, np.float32))
    # defaults reproduce the no-params execution exactly
    x = jnp.asarray(np.random.default_rng(8).standard_normal(1024),
                    jnp.float32)
    res_default = c(x)
    res_params = c(x, p)
    for k in res_default:
        np.testing.assert_array_equal(np.asarray(res_default[k]),
                                      np.asarray(res_params[k]))


def test_hot_swapped_fir_taps_change_output_without_recompile():
    T = 512
    g = SignalGraph("hs")
    g.fir("f", "input", taps=[1.0, 0.0, 0.0])
    g.outputs("f")
    c = g.compile(T)
    run = c.jit()
    x = jnp.asarray(np.random.default_rng(9).standard_normal(T), jnp.float32)
    y_id = run(x, c.init_params())["f"]
    np.testing.assert_allclose(np.asarray(y_id), np.asarray(x), atol=1e-6)
    swapped = {"f": {"taps": jnp.asarray([0.0, 1.0, 0.0])}}  # pure delay
    y_del = np.asarray(run(x, swapped)["f"])
    np.testing.assert_allclose(y_del[1:], np.asarray(x)[:-1], atol=1e-6)


def test_value_and_grad_wrt_validation():
    g = SignalGraph("vw")
    g.fir("f", "input", taps=[1.0, 0.5])
    g.outputs("f")
    c = g.compile(128)
    vag = c.value_and_grad(lambda outs: jnp.mean(outs["f"] ** 2),
                           wrt=("nope",))
    with pytest.raises(ValueError, match="nope"):
        vag(c.init_params(), jnp.zeros(128))


def test_unified_plan_cache_clear():
    import repro.signal as sig

    sig.clear_plan_caches()
    assert sig.plan_cache_info()["total"] == 0
    x = jnp.asarray(np.random.default_rng(10).standard_normal(64),
                    jnp.float32)
    sig.fft(x)
    sig.fir(x, jnp.asarray(np.ones(5, np.float32)))
    sig.dwt(x)
    sig.stft(x, frame=32, hop=16)
    info = sig.plan_cache_info()
    assert info["fft"] >= 1 and info["fir"] >= 1 and info["dwt"] >= 1
    assert info.get("stft_frame", 0) >= 1    # spectrogram rides the cache
    assert info["total"] >= 4
    sig.clear_plan_caches()
    assert sig.plan_cache_info()["total"] == 0
    # rebuilt transparently on the next call
    sig.fft(x)
    assert sig.plan_cache_info()["fft"] >= 1
