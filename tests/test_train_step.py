"""Train-step factory: microbatched gradient accumulation must equal the
single-shot step, and losses must decrease over a short run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.train import init_train_state, make_train_step
from repro.models.zoo import get_model


def _setup(microbatch):
    cfg = get_config("starcoder2-3b").reduced(
        n_layers=2, d_model=32, n_heads=4, d_ff=64, vocab=128)
    cfg = dataclasses.replace(cfg, microbatch=microbatch, remat=False)
    bundle = get_model(cfg)
    params, opt = init_train_state(bundle, jax.random.PRNGKey(0))
    return bundle, params, opt


def test_microbatch_equals_single_shot():
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                          0, 128)}
    outs = {}
    for k in (1, 2, 4):
        bundle, params, opt = _setup(k)
        step = jax.jit(make_train_step(bundle))
        p2, o2, m = step(params, opt, batch)
        outs[k] = (float(m["loss"]),
                   np.asarray(jax.tree_util.tree_leaves(p2)[0]))
    assert abs(outs[1][0] - outs[2][0]) < 1e-5
    assert abs(outs[1][0] - outs[4][0]) < 1e-5
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=2e-4, atol=2e-4)


def test_loss_decreases():
    from repro.optim.adamw import cosine_schedule
    bundle, params, opt = _setup(1)
    step = jax.jit(make_train_step(bundle, cosine_schedule(5e-3, 3, 1000)),
                   donate_argnums=(0, 1))
    losses = []
    key = jax.random.PRNGKey(2)
    for i in range(30):
        batch = {"tokens": jax.random.randint(
            jax.random.fold_in(key, i % 4), (8, 16), 0, 128)}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
    assert int(opt.step) == 30
