"""benchmarks/trajectory.py: append/compare plus the cross-PR
time-series table (`timeseries` subcommand) added for the SigSched
sweep."""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.trajectory import (append_entry, compare, format_timeseries,
                                   load_trajectory, make_entry, timeseries)


def _write(root, pr, bench, metrics):
    append_entry(os.path.join(root, f"BENCH_PR{pr}.json"),
                 make_entry(pr, bench, metrics))


def test_timeseries_rows_in_pr_order_with_schema_drift(tmp_path):
    root = str(tmp_path)
    _write(root, 8, "svc", {"sched_sweep": [
        {"p95_deadline_cycles": 100.0}, {"p95_deadline_cycles": 40.0}]})
    _write(root, 6, "svc", {})                      # pre-sched schema
    _write(root, 7, "other", {"x": 1})
    rows = timeseries(load_trajectory(root), "svc",
                      ["sched_sweep.1.p95_deadline_cycles"])
    assert [r["pr"] for r in rows] == [6, 8]
    assert rows[0]["sched_sweep.1.p95_deadline_cycles"] is None
    assert rows[1]["sched_sweep.1.p95_deadline_cycles"] == 40.0
    table = format_timeseries(rows, ["sched_sweep.1.p95_deadline_cycles"])
    lines = table.splitlines()
    assert lines[0].split() == ["pr", "sched_sweep.1.p95_deadline_cycles"]
    assert lines[1].split() == ["6", "-"]
    assert lines[2].split() == ["8", "40"]


def test_timeseries_cli(tmp_path, capsys):
    from benchmarks.trajectory import main
    _write(str(tmp_path), 9, "svc", {"a": {"b": 3.5}})
    main(["timeseries", "svc", "a.b", "--root", str(tmp_path)])
    out = capsys.readouterr().out
    assert "3.5" in out and "a.b" in out


def test_append_replaces_same_pr_bench(tmp_path):
    path = str(tmp_path / "BENCH_PR9.json")
    append_entry(path, make_entry(9, "svc", {"v": 1}))
    entries = append_entry(path, make_entry(9, "svc", {"v": 2}))
    assert len(entries) == 1 and entries[0]["metrics"]["v"] == 2
    with open(path) as f:
        assert json.load(f)[0]["metrics"]["v"] == 2


def test_compare_flags_regression_direction():
    old = make_entry(9, "svc", {"p95": 100.0})
    new = make_entry(10, "svc", {"p95": 150.0, "extra": 1})
    (rec,) = compare(old, new, ["p95"], tolerance=0.10)
    assert rec["regressed"] and rec["ratio"] == 1.5
    (rec,) = compare(old, new, ["p95"], tolerance=0.10,
                     higher_is_better=True)
    assert not rec["regressed"]
    (rec,) = compare(old, new, ["missing.key"])
    assert rec.get("missing") and not rec["regressed"]
